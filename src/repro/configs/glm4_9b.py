"""GLM4-9B — dense decoder, RoPE, extreme GQA (kv=2).

[hf:THUDM/glm-4-9b] 40L, d_model 4096, 32 heads (GQA kv=2), d_ff 13696,
vocab 151552, QKV bias.
"""
from repro.configs.base import ArchConfig, register


@register("glm4-9b")
def glm4_9b() -> ArchConfig:
    return ArchConfig(
        name="glm4-9b",
        family="dense",
        source="hf:THUDM/glm-4-9b",
        num_layers=40,
        d_model=4096,
        vocab_size=151552,
        attention="gqa",
        num_heads=32,
        num_kv_heads=2,
        head_dim=128,
        qkv_bias=True,
        d_ff=13696,
        supports_long_context=True,
        remat="full",
    )
