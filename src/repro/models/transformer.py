"""Decoder / encoder-decoder / hybrid / SSM trunks with scanned layer stacks.

All trunks share one contract:

* ``init_model(key, cfg) -> params``
* ``forward(params, cfg, tokens, prefix_embeds=None, train=False)``
    -> (hidden (B,S,d), aux)   — full-sequence causal pass (train / prefill)
* ``init_cache(cfg, batch, max_len, dtype) -> cache``
* ``decode_step(params, cfg, cache, token (B,1), pos) -> (hidden (B,1,d), cache)``

Layers are **scanned** (params stacked on a leading layer axis) so HLO size
and compile time are O(1) in depth — essential for lowering 62-layer models
against a 512-device mesh. Remat (``cfg.remat``) wraps the scan body.

Hybrid (Zamba2-style) trunks scan *groups*: ``shared_attn_every`` Mamba2
layers followed by one application of a single shared attention+MLP block
(one weight copy, per-application KV cache), with a tail scan for the
remainder group.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.constraints import constrain
from repro.models import attention as attn
from repro.models.common import (
    dtype_of,
    embed_init,
    init_linear,
    init_rmsnorm,
    linear,
    rmsnorm,
    split_keys,
)
from repro.models.mlp import init_mlp, mlp_forward
from repro.models.moe import init_moe, moe_forward
from repro.models.ssm import (
    init_mamba2,
    init_mamba2_cache,
    mamba2_decode,
    mamba2_forward,
)

# ---------------------------------------------------------------------------
# Single blocks
# ---------------------------------------------------------------------------


def init_attn_block(key, cfg, dtype, *, dense_ff: int = 0, cross: bool = False):
    """Standard transformer block: attn (+ cross) + FFN (dense or MoE)."""
    ks = split_keys(key, 6)
    p = {"ln1": init_rmsnorm(cfg.d_model, dtype)}
    if cfg.attention == "mla":
        p["attn"] = attn.init_mla(ks[0], cfg, dtype)
    else:
        p["attn"] = attn.init_gqa(ks[0], cfg, dtype)
    if cross:
        p["ln_x"] = init_rmsnorm(cfg.d_model, dtype)
        p["xattn"] = attn.init_gqa(ks[1], cfg, dtype, cross=True)
    p["ln2"] = init_rmsnorm(cfg.d_model, dtype)
    if dense_ff:
        p["mlp"] = init_mlp(ks[2], cfg.d_model, dense_ff, dtype, cfg.mlp)
    elif cfg.num_experts:
        p["moe"] = init_moe(ks[3], cfg, dtype)
    elif cfg.d_ff:
        p["mlp"] = init_mlp(ks[2], cfg.d_model, cfg.d_ff, dtype, cfg.mlp)
    return p


def attn_block_forward(p, cfg, x, *, causal=True, window=0, enc_out=None, block_k=512):
    """Full-sequence block. Returns (x, aux_loss, cache_kv or None)."""
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.attention == "mla":
        y, kv = attn.mla_forward(p["attn"], cfg, h, window=window, block_k=block_k,
                                 return_cache=True)
    else:
        y, kv = attn.gqa_prefill(p["attn"], cfg, h, window=window, block_k=block_k)
    x = x + y
    if enc_out is not None and "xattn" in p:
        h = rmsnorm(p["ln_x"], x, cfg.norm_eps)
        x = x + attn.gqa_forward(p["xattn"], cfg, h, kv_src=enc_out, causal=False,
                                 use_rope=False, block_k=block_k)
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        y, aux = moe_forward(p["moe"], cfg, h)
    elif "mlp" in p:
        y = mlp_forward(p["mlp"], h)
    else:
        y = jnp.zeros_like(h)
    x = x + y
    x = constrain(x, "data", None, None)
    return x, aux, kv


def attn_block_decode(p, cfg, x, cache, pos, *, window=0, enc_out=None):
    """Single-token block step. cache: dict for this layer."""
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.attention == "mla":
        y, new_attn = attn.mla_decode(p["attn"], cfg, h, cache["attn"], pos, window=window)
    else:
        y, new_attn = attn.gqa_decode(p["attn"], cfg, h, cache["attn"], pos, window=window)
    x = x + y
    if "xattn" in p and "cross_k" in cache:
        h = rmsnorm(p["ln_x"], x, cfg.norm_eps)
        x = x + _cross_decode(p["xattn"], cfg, h, cache["cross_k"], cache["cross_v"])
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if "moe" in p:
        y, _ = moe_forward(p["moe"], cfg, h)
    elif "mlp" in p:
        y = mlp_forward(p["mlp"], h)
    else:
        y = jnp.zeros_like(h)
    new_cache = dict(cache)
    new_cache["attn"] = new_attn
    return x + y, new_cache


def _cross_decode(p, cfg, x, ck, cv):
    """Cross-attention for one decoder token against precomputed enc K/V."""
    B = x.shape[0]
    H, Hkv, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = linear(p["wq"], x).reshape(B, 1, H, D)
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32) * (1.0 / math.sqrt(D))
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, ck.astype(jnp.float32))
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", pr, cv.astype(jnp.float32))
    return linear(p["wo"], o.reshape(B, 1, H * D).astype(x.dtype))


def init_attn_cache(cfg, batch, max_len, dtype):
    if cfg.attention == "mla":
        return {"attn": attn.init_mla_cache(cfg, batch, max_len, dtype)}
    return {"attn": attn.init_gqa_cache(cfg, batch, max_len, dtype)}


# ---------------------------------------------------------------------------
# SSM block (Mamba2) — used by ssm + hybrid families
# ---------------------------------------------------------------------------


def init_ssm_block(key, cfg, dtype):
    ks = split_keys(key, 2)
    return {"ln": init_rmsnorm(cfg.d_model, dtype), "mamba": init_mamba2(ks[0], cfg, dtype)}


def ssm_block_forward(p, cfg, x):
    h = rmsnorm(p["ln"], x, cfg.norm_eps)
    x = x + mamba2_forward(p["mamba"], cfg, h)
    return constrain(x, "data", None, None)


def ssm_block_decode(p, cfg, x, cache):
    h = rmsnorm(p["ln"], x, cfg.norm_eps)
    y, new_cache = mamba2_decode(p["mamba"], cfg, h, cache)
    return x + y, new_cache


# ---------------------------------------------------------------------------
# Model init
# ---------------------------------------------------------------------------


def _stack_init(fn, key, n: int):
    """Initialise n layers with stacked (scan-ready) parameters."""
    keys = jax.random.split(key, n)
    return jax.vmap(fn)(keys)


def init_model(key, cfg):
    dtype = dtype_of(cfg.param_dtype)
    ks = split_keys(key, 10)
    p = {"embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype)}
    if cfg.frontend_dim:
        p["frontend_proj"] = init_linear(ks[7], cfg.frontend_dim, cfg.d_model, dtype)

    if cfg.family in ("ssm",):
        p["layers"] = _stack_init(lambda k: init_ssm_block(k, cfg, dtype), ks[1], cfg.num_layers)
    elif cfg.family == "hybrid":
        every = cfg.shared_attn_every
        n_groups, rem = divmod(cfg.num_layers, every)
        p["groups"] = _stack_init(
            lambda k: jax.vmap(lambda kk: init_ssm_block(kk, cfg, dtype))(
                jax.random.split(k, every)
            ),
            ks[1],
            n_groups,
        )
        if rem:
            p["tail"] = _stack_init(lambda k: init_ssm_block(k, cfg, dtype), ks[2], rem)
        # single shared attention+MLP block (one weight copy)
        p["shared"] = init_attn_block(ks[3], cfg, dtype, dense_ff=cfg.d_ff)
    else:
        n_scanned = cfg.num_layers - cfg.first_dense_layers
        if cfg.first_dense_layers:
            p["first"] = _stack_init(
                lambda k: init_attn_block(k, cfg, dtype,
                                          dense_ff=cfg.dense_d_ff or cfg.d_ff),
                ks[4], cfg.first_dense_layers,
            )
        cross = cfg.is_encoder_decoder
        p["layers"] = _stack_init(
            lambda k: init_attn_block(k, cfg, dtype, cross=cross), ks[1], n_scanned
        )
        if cfg.is_encoder_decoder:
            p["encoder"] = {
                "layers": _stack_init(
                    lambda k: init_attn_block(k, cfg, dtype), ks[5], cfg.encoder_layers
                ),
                "norm": init_rmsnorm(cfg.d_model, dtype),
            }
    p["final_norm"] = init_rmsnorm(cfg.d_model, dtype)
    return p


# ---------------------------------------------------------------------------
# Embedding / front-end
# ---------------------------------------------------------------------------


def embed_tokens(p, cfg, tokens, prefix_embeds=None):
    """tokens: (B, S_text) int32; prefix_embeds: (B, S_pre, F) or None."""
    dtype = dtype_of(cfg.compute_dtype)
    x = p["embed"][tokens].astype(dtype)
    if prefix_embeds is not None:
        pre = prefix_embeds.astype(dtype)
        if "frontend_proj" in p:
            pre = linear(p["frontend_proj"], pre)
        x = jnp.concatenate([pre, x], axis=1)
    return constrain(x, "data", None, None)


# ---------------------------------------------------------------------------
# Full-sequence forward
# ---------------------------------------------------------------------------


def _maybe_remat(fn, cfg, train: bool):
    if not train or cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.checkpoint_dots)


def _run_encoder(p, cfg, frames, train: bool):
    """Bidirectional encoder over front-end frame embeddings (B, S_enc, d)."""
    x = frames

    def body(x, lp):
        x, _, _ = attn_block_forward(lp, cfg, x, causal=False)
        return x, None

    body = _maybe_remat(body, cfg, train)
    x, _ = jax.lax.scan(body, x, p["encoder"]["layers"])
    return rmsnorm(p["encoder"]["norm"], x, cfg.norm_eps)


def forward(params, cfg, tokens, prefix_embeds=None, *, train: bool = False,
            window: Optional[int] = None):
    """Causal full-sequence pass. Returns (hidden (B,S,d), aux dict)."""
    win = cfg.sliding_window if window is None else window
    aux_total = jnp.zeros((), jnp.float32)
    enc_out = None
    if cfg.is_encoder_decoder:
        pre = prefix_embeds.astype(dtype_of(cfg.compute_dtype))
        if "frontend_proj" in params:
            pre = linear(params["frontend_proj"], pre)
        enc_out = _run_encoder(params, cfg, pre, train)
        x = embed_tokens(params, cfg, tokens)
    else:
        x = embed_tokens(params, cfg, tokens, prefix_embeds)

    if cfg.family == "ssm":
        def body(x, lp):
            return ssm_block_forward(lp, cfg, x), None

        body = _maybe_remat(body, cfg, train)
        x, _ = jax.lax.scan(body, x, params["layers"])
    elif cfg.family == "hybrid":
        def grp(x, gp):
            def inner(x, lp):
                return ssm_block_forward(lp, cfg, x), None

            x, _ = jax.lax.scan(inner, x, gp)
            x, _, _ = attn_block_forward(params["shared"], cfg, x, window=win)
            return x, None

        grp = _maybe_remat(grp, cfg, train)
        x, _ = jax.lax.scan(grp, x, params["groups"])
        if "tail" in params:
            def inner(x, lp):
                return ssm_block_forward(lp, cfg, x), None

            x, _ = jax.lax.scan(_maybe_remat(inner, cfg, train), x, params["tail"])
    else:
        if "first" in params:
            dense_cfg = cfg
            def fbody(x, lp):
                x, _, _ = attn_block_forward(lp, dense_cfg, x, window=win)
                return x, None

            x, _ = jax.lax.scan(_maybe_remat(fbody, cfg, train), x, params["first"])

        def body(x, lp):
            x, aux, _ = attn_block_forward(lp, cfg, x, window=win, enc_out=enc_out)
            return x, aux

        body = _maybe_remat(body, cfg, train)
        x, auxs = jax.lax.scan(body, x, params["layers"])
        aux_total = aux_total + jnp.sum(auxs)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, {"moe_aux": aux_total}


# ---------------------------------------------------------------------------
# KV/State cache
# ---------------------------------------------------------------------------


def init_cache(cfg, batch: int, max_len: int, dtype=None):
    if dtype is None:
        dtype = dtype_of(cfg.cache_dtype or cfg.compute_dtype)

    def stack(make, n):
        one = make()
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), one
        )

    if cfg.family == "ssm":
        return {"layers": stack(lambda: init_mamba2_cache(cfg, batch, dtype), cfg.num_layers)}
    if cfg.family == "hybrid":
        every = cfg.shared_attn_every
        n_groups, rem = divmod(cfg.num_layers, every)
        cache = {
            "groups": stack(
                lambda: stack(lambda: init_mamba2_cache(cfg, batch, dtype), every),
                n_groups,
            ),
            "shared": stack(lambda: init_attn_cache(cfg, batch, max_len, dtype), n_groups),
        }
        if rem:
            cache["tail"] = stack(lambda: init_mamba2_cache(cfg, batch, dtype), rem)
        return cache
    n_scanned = cfg.num_layers - cfg.first_dense_layers
    cache = {"layers": stack(lambda: init_attn_cache(cfg, batch, max_len, dtype), n_scanned)}
    if cfg.first_dense_layers:
        cache["first"] = stack(
            lambda: init_attn_cache(cfg, batch, max_len, dtype), cfg.first_dense_layers
        )
    if cfg.is_encoder_decoder:
        Hkv, D = cfg.num_kv_heads, cfg.head_dim
        cache["cross"] = {
            "k": jnp.zeros((n_scanned, batch, cfg.encoder_seq_len, Hkv, D), dtype),
            "v": jnp.zeros((n_scanned, batch, cfg.encoder_seq_len, Hkv, D), dtype),
        }
    return cache


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------


def decode_step(params, cfg, cache, token, pos, *, window: Optional[int] = None):
    """One autoregressive step. token: (B, 1) int32; pos: scalar int32
    (lockstep — every row at the same position) or (B,) int32 per-row
    positions (the serving plane's slot-managed batch; rows advance
    independently and the attention paths mask/write per row).

    Returns (hidden (B,1,d), new_cache).
    """
    win = cfg.sliding_window if window is None else window
    x = embed_tokens(params, cfg, token)

    if cfg.family == "ssm":
        def body(x, xs):
            lp, lc = xs
            x, nc = ssm_block_decode(lp, cfg, x, lc)
            return x, nc

        x, new_layers = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
        return x_final(params, cfg, x), {"layers": new_layers}

    if cfg.family == "hybrid":
        def grp(x, xs):
            gp, gc, sc = xs  # group params, group mamba caches, shared attn cache

            def inner(x, ys):
                lp, lc = ys
                x, nc = ssm_block_decode(lp, cfg, x, lc)
                return x, nc

            x, ngc = jax.lax.scan(inner, x, (gp, gc))
            x, nsc = attn_block_decode(params["shared"], cfg, x, sc, pos, window=win)
            return x, (ngc, nsc)

        x, (ngroups, nshared) = jax.lax.scan(
            grp, x, (params["groups"], cache["groups"], cache["shared"])
        )
        new_cache = {"groups": ngroups, "shared": nshared}
        if "tail" in cache:
            def inner(x, ys):
                lp, lc = ys
                x, nc = ssm_block_decode(lp, cfg, x, lc)
                return x, nc

            x, ntail = jax.lax.scan(inner, x, (params["tail"], cache["tail"]))
            new_cache["tail"] = ntail
        return x_final(params, cfg, x), new_cache

    new_cache = {}
    if "first" in params:
        def fbody(x, xs):
            lp, lc = xs
            x, nc = attn_block_decode(lp, cfg, x, lc, pos, window=win)
            return x, nc

        x, nfirst = jax.lax.scan(fbody, x, (params["first"], cache["first"]))
        new_cache["first"] = nfirst

    if cfg.is_encoder_decoder:
        def body(x, xs):
            lp, lc, ck, cv = xs
            lc = dict(lc)
            lc["cross_k"], lc["cross_v"] = ck, cv
            x, nc = attn_block_decode(lp, cfg, x, lc, pos, window=win)
            nc.pop("cross_k"), nc.pop("cross_v")
            return x, nc

        x, nlayers = jax.lax.scan(
            body, x, (params["layers"], cache["layers"], cache["cross"]["k"], cache["cross"]["v"])
        )
        new_cache["cross"] = cache["cross"]
    else:
        def body(x, xs):
            lp, lc = xs
            x, nc = attn_block_decode(lp, cfg, x, lc, pos, window=win)
            return x, nc

        x, nlayers = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
    new_cache["layers"] = nlayers
    return x_final(params, cfg, x), new_cache


def x_final(params, cfg, x):
    return rmsnorm(params["final_norm"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Prefill (fills the cache, returns last hidden)
# ---------------------------------------------------------------------------


def prefill(params, cfg, tokens, prefix_embeds=None, *, window: Optional[int] = None,
            max_len: Optional[int] = None):
    """Full-sequence pass that also materializes the KV cache.

    For attention families this re-runs the forward and collects per-layer
    roped K/V; SSM/hybrid prefill reuses forward + final states.
    ``max_len`` sizes the cache with decode headroom (defaults to S).
    Returns (hidden (B,S,d), cache).
    """
    win = cfg.sliding_window if window is None else window
    enc_out = None
    if cfg.is_encoder_decoder:
        pre = prefix_embeds.astype(dtype_of(cfg.compute_dtype))
        if "frontend_proj" in params:
            pre = linear(params["frontend_proj"], pre)
        enc_out = _run_encoder(params, cfg, pre, False)
        x = embed_tokens(params, cfg, tokens)
    else:
        x = embed_tokens(params, cfg, tokens, prefix_embeds)
    B, S, _ = x.shape

    if cfg.family == "ssm":
        def body(x, lp):
            h = rmsnorm(lp["ln"], x, cfg.norm_eps)
            y, (state, tails) = mamba2_forward(lp["mamba"], cfg, h, return_state=True)
            return x + y, (state, tails)

        x, (states, (tx, tB, tC)) = jax.lax.scan(body, x, params["layers"])
        cache = init_cache(cfg, B, max_len or S)
        lc = cache["layers"]
        cache["layers"] = {
            "state": states,
            "conv_x": tx.astype(lc["conv_x"].dtype),
            "conv_B": tB.astype(lc["conv_B"].dtype),
            "conv_C": tC.astype(lc["conv_C"].dtype),
        }
        return x_final(params, cfg, x), cache

    ML = max_len or S
    cache = init_cache(cfg, B, ML)
    if cfg.family == "hybrid":
        # hybrid prefill is exercised via decode-loop in tests; dry-run uses
        # forward(); production prefill would mirror the ssm path above.
        x, _ = forward(params, cfg, tokens, prefix_embeds, window=win)
        return x, cache

    def body(x, lp):
        x, aux, kv = attn_block_forward(lp, cfg, x, window=win, enc_out=enc_out)
        return x, kv

    if "first" in params:
        x, kvf = jax.lax.scan(body, x, params["first"])
        cache["first"]["attn"] = _cache_from_kv(cfg, kvf, S, ML)
    x, kvs = jax.lax.scan(body, x, params["layers"])
    cache["layers"]["attn"] = _cache_from_kv(cfg, kvs, S, ML)
    if cfg.is_encoder_decoder:
        # precompute cross K/V from encoder output for every layer
        def xkv(_, lp):
            Hkv, D = cfg.num_kv_heads, cfg.head_dim
            k = linear(lp["xattn"]["wk"], enc_out).reshape(B, -1, Hkv, D)
            v = linear(lp["xattn"]["wv"], enc_out).reshape(B, -1, Hkv, D)
            return None, (k, v)

        _, (cks, cvs) = jax.lax.scan(xkv, None, params["layers"])
        cache["cross"] = {"k": cks, "v": cvs}
    return x_final(params, cfg, x), cache


def _cache_from_kv(cfg, kv, S, max_len=None):
    """Place prefilled K/V into cache slots.

    Ring-buffer invariant (sliding window): token t lives at slot t % slots,
    so the tail slice of the last `slots` tokens is rolled by S % slots to
    line up with the slot the next decode step will overwrite. Without a
    window, slots [S:max_len) are zero headroom for decode.
    """
    ML = max_len or S
    slots = min(ML, cfg.sliding_window) if cfg.sliding_window else ML

    def place(x):  # x: (L, B, S, ...) -> (L, B, slots, ...)
        if cfg.sliding_window and slots < S:
            tail = x[:, :, -slots:]
            return jnp.roll(tail, S % slots, axis=2)
        if slots > S:  # decode headroom
            pad = [(0, 0)] * x.ndim
            pad[2] = (0, slots - S)
            return jnp.pad(x, pad)
        return x[:, :, -slots:]

    if cfg.attention == "mla":
        c, kr = kv
        return {"c": place(c), "kr": place(kr)}
    k, v = kv
    return {"k": place(k), "v": place(v)}
