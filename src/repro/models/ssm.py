"""Mamba2 block via State-Space Duality (SSD), arXiv:2405.21060.

TPU adaptation: the CUDA selective-scan is replaced by the **chunked SSD
algorithm** — intra-chunk work is dense (C·Bᵀ ∘ decay-mask)·X matmuls that
map onto the MXU, and only the O(S/Q) inter-chunk state carry is a
``lax.scan``. ``repro/kernels/ssd_scan.py`` is the fused Pallas twin of the
chunk recurrence; this module is the reference / dry-run path.

Projections are kept **separate** (w_z, w_x, w_B, w_C, w_dt instead of one
fused in_proj) so the inner dimension shards cleanly over the "model" axis:
z/x/dt are per-inner-channel (tensor parallel), B/C are small shared state
projections (replicated). This is the TPU-native layout; fusing them (as the
CUDA kernel does) would interleave shard boundaries.

Decode is the O(1) recurrent form: state (B, H, P, N) plus (K-1)-deep causal
conv ring buffers — this is why SSM/hybrid archs run ``long_500k``.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.constraints import constrain
from repro.models.common import dense_init, init_rmsnorm, linear, rmsnorm, split_keys


def _dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = d_inner // cfg.ssm_head_dim
    return d_inner, H, cfg.ssm_head_dim, cfg.ssm_state


def init_mamba2(key, cfg, dtype):
    d_inner, H, P, N = _dims(cfg)
    K = cfg.ssm_conv
    ks = split_keys(key, 8)
    # dt bias initialised so softplus(dt_bias) spans [1e-3, 1e-1]
    dt = jnp.exp(
        jax.random.uniform(ks[6], (H,)) * (math.log(0.1) - math.log(1e-3))
        + math.log(1e-3)
    )
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))
    return {
        "w_z": {"w": dense_init(ks[0], cfg.d_model, d_inner, dtype)},
        "w_x": {"w": dense_init(ks[1], cfg.d_model, d_inner, dtype)},
        "w_B": {"w": dense_init(ks[2], cfg.d_model, N, dtype)},
        "w_C": {"w": dense_init(ks[3], cfg.d_model, N, dtype)},
        "w_dt": {"w": dense_init(ks[4], cfg.d_model, H, dtype)},
        "conv_x": (jax.random.normal(ks[5], (K, d_inner)) / math.sqrt(K)).astype(dtype),
        "conv_B": (jax.random.normal(ks[5], (K, N)) / math.sqrt(K)).astype(dtype),
        "conv_C": (jax.random.normal(ks[5], (K, N)) / math.sqrt(K)).astype(dtype),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "norm": init_rmsnorm(d_inner, dtype),
        "out_proj": {"w": dense_init(ks[7], d_inner, cfg.d_model, dtype,
                                     scale=1.0 / math.sqrt(2 * max(cfg.num_layers, 1)))},
    }


def _causal_conv(x, w):
    """x: (B, S, C); w: (K, C) depthwise causal conv."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    return sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(K))


def ssd_chunked(x, dt, A_log, B, C, D, *, chunk: int):
    """Chunked SSD scan (reference implementation, fp32 internals).

    x: (B, S, H, P); dt: (B, S, H) post-softplus; A_log: (H,);
    B, C: (B, S, N) (single group, shared across heads); D: (H,).
    Returns y: (B, S, H, P) and final state (B, H, P, N).
    """
    Bsz, S, H, P = x.shape
    N = B.shape[-1]
    assert S % chunk == 0, f"seq {S} % chunk {chunk} != 0"
    nc = S // chunk

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    a_log = -jnp.exp(A_log)[None, None, :] * dtf  # (B,S,H), negative
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)

    xc = xf.reshape(Bsz, nc, chunk, H, P)
    dtc = dtf.reshape(Bsz, nc, chunk, H)
    ac = a_log.reshape(Bsz, nc, chunk, H)
    Bc = Bf.reshape(Bsz, nc, chunk, N)
    Cc = Cf.reshape(Bsz, nc, chunk, N)

    cum = jnp.cumsum(ac, axis=2)  # inclusive (B,nc,Q,H)
    total = cum[:, :, -1, :]  # (B,nc,H)

    # intra-chunk: Y[i] = sum_{j<=i} exp(cum_i - cum_j) (C_i . B_j) dt_j x_j
    scores = jnp.einsum("bcis,bcjs->bcij", Cc, Bc, preferred_element_type=jnp.float32)
    dec = cum[:, :, :, None, :] - cum[:, :, None, :, :]
    iu = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(iu[None, None, :, :, None], jnp.exp(dec), 0.0)
    G = scores[..., None] * L  # (B,nc,Q,Q,H)
    xdt = xc * dtc[..., None]  # (B,nc,Q,H,P)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", G, xdt)

    # chunk-final states: S_c = sum_j exp(total - cum_j) B_j ⊗ (dt_j x_j)
    w_state = jnp.exp(total[:, :, None, :] - cum)  # (B,nc,Q,H)
    S_chunk = jnp.einsum("bcjh,bcjs,bcjhp->bchps", w_state, Bc, xdt)

    # inter-chunk carry: state entering each chunk
    def carry_fn(s, inp):
        s_chunk, lam = inp  # (B,H,P,N), (B,H)
        s_next = s * jnp.exp(lam)[:, :, None, None] + s_chunk
        return s_next, s

    s0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    s_final, s_in = jax.lax.scan(
        carry_fn,
        s0,
        (S_chunk.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2)),
    )
    s_in = s_in.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N)

    # inter-chunk output: Y_inter[i] = exp(cum_i) C_i . S_in
    y_inter = jnp.einsum("bcih,bcis,bchps->bcihp", jnp.exp(cum), Cc, s_in)

    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    y = y + D[None, None, :, None] * xf
    return y.astype(x.dtype), s_final


def mamba2_forward(p, cfg, x, *, return_state: bool = False):
    """Full-sequence Mamba2 block. x: (B, S, d_model)."""
    Bsz, S, _ = x.shape
    d_inner, H, P, N = _dims(cfg)
    z = linear(p["w_z"], x)
    xr = linear(p["w_x"], x)
    Br = linear(p["w_B"], x)
    Cr = linear(p["w_C"], x)
    dt = linear(p["w_dt"], x)
    xs = jax.nn.silu(_causal_conv(xr, p["conv_x"]))
    Bm = jax.nn.silu(_causal_conv(Br, p["conv_B"]))
    Cm = jax.nn.silu(_causal_conv(Cr, p["conv_C"]))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    y, state = ssd_chunked(
        xs.reshape(Bsz, S, H, P), dt, p["A_log"], Bm, Cm, p["D"],
        chunk=min(cfg.ssm_chunk, S),
    )
    y = y.reshape(Bsz, S, d_inner) * jax.nn.silu(z)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    out = linear(p["out_proj"], y)
    if return_state:
        K = cfg.ssm_conv
        tails = (xr[:, -(K - 1):, :], Br[:, -(K - 1):, :], Cr[:, -(K - 1):, :])
        return out, (state, tails)
    return out


def init_mamba2_cache(cfg, batch: int, dtype):
    d_inner, H, P, N = _dims(cfg)
    K = cfg.ssm_conv
    return {
        "conv_x": jnp.zeros((batch, K - 1, d_inner), dtype),
        "conv_B": jnp.zeros((batch, K - 1, N), dtype),
        "conv_C": jnp.zeros((batch, K - 1, N), dtype),
        "state": jnp.zeros((batch, H, P, N), jnp.float32),
    }


def _conv_step(buf, new, w):
    """Ring conv step. buf: (B, K-1, C); new: (B, C); w: (K, C)."""
    win = jnp.concatenate([buf, new[:, None, :]], axis=1)  # (B, K, C)
    out = jnp.einsum("bkc,kc->bc", win, w)
    return out, win[:, 1:, :]


def mamba2_decode(p, cfg, x, cache):
    """Single-token recurrent step. x: (B, 1, d_model)."""
    Bsz = x.shape[0]
    d_inner, H, P, N = _dims(cfg)
    x0 = x[:, 0, :]
    z = linear(p["w_z"], x0)
    xr = linear(p["w_x"], x0)
    Br = linear(p["w_B"], x0)
    Cr = linear(p["w_C"], x0)
    dt = linear(p["w_dt"], x0)
    xs, ncx = _conv_step(cache["conv_x"], xr, p["conv_x"])
    Bm, ncB = _conv_step(cache["conv_B"], Br, p["conv_B"])
    Cm, ncC = _conv_step(cache["conv_C"], Cr, p["conv_C"])
    xs, Bm, Cm = jax.nn.silu(xs), jax.nn.silu(Bm), jax.nn.silu(Cm)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    lam = jnp.exp(-jnp.exp(p["A_log"])[None, :] * dt)  # (B,H)
    xh = xs.reshape(Bsz, H, P).astype(jnp.float32)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, xh, Bm.astype(jnp.float32))
    state = cache["state"] * lam[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, Cm.astype(jnp.float32))
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(Bsz, 1, d_inner).astype(x.dtype) * jax.nn.silu(z)[:, None, :]
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    new_cache = {
        "conv_x": ncx.astype(cache["conv_x"].dtype),
        "conv_B": ncB.astype(cache["conv_B"].dtype),
        "conv_C": ncC.astype(cache["conv_C"].dtype),
        "state": state,
    }
    return linear(p["out_proj"], y), new_cache