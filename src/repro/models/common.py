"""Shared model building blocks: initializers, linear layers, norms, RoPE.

Parameters are plain nested dicts of jnp arrays (no flax). Every module is a
pair of functions: ``init_*(key, ...) -> params`` and an apply function.
Scanned layer stacks hold parameters stacked along a leading layer axis.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, in_dim: int, out_dim: int, dtype, scale: float = 1.0):
    """LeCun-normal style init used for all projection matrices."""
    std = scale / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim)) * std).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype):
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)


def init_linear(key, in_dim: int, out_dim: int, dtype, bias: bool = False, scale: float = 1.0):
    p = {"w": dense_init(key, in_dim, out_dim, dtype, scale)}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
    return p


def linear(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(dim: int, dtype):
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(p, x, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(dim: int, dtype):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(p, x, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies for rotary embedding over `dim` channels."""
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary position embedding (halves convention).

    x: (B, S, H, D) or (B, S, D); positions: (S,) or (B, S).
    """
    dim = x.shape[-1]
    inv_freq = rope_frequencies(dim, theta)  # (dim/2,)
    pos = positions.astype(jnp.float32)
    angles = jnp.einsum("...s,f->...sf", pos, inv_freq)  # (S, d/2) or (B, S, d/2)
    if angles.ndim == 2:  # (S, d/2) -> broadcast over batch
        angles = angles[None]
    if x.ndim == 4:  # head axis present
        angles = angles[:, :, None, :]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}[name]


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


def causal_mask(q_len: int, kv_len: int, q_offset: int = 0) -> jnp.ndarray:
    """Boolean (q_len, kv_len) mask; True = attend."""
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    k_pos = jnp.arange(kv_len)[None, :]
    return k_pos <= q_pos


def sliding_window_mask(q_len: int, kv_len: int, window: int, q_offset: int = 0) -> jnp.ndarray:
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    k_pos = jnp.arange(kv_len)[None, :]
    return (k_pos <= q_pos) & (k_pos > q_pos - window)
