"""Mixture-of-Experts block (DeepSeek-V2 / DBRX style).

TPU-native design decisions:

* **Sort/scatter dispatch, not one-hot einsum.** The classic GShard dispatch
  einsum multiplies by a (tokens, E, C) one-hot tensor; XLA counts those as
  real FLOPs and they rival the expert matmuls themselves at 160-expert
  scale, wrecking both the roofline accounting and HBM. We instead compute
  per-token top-k, sort assignments by expert, and scatter tokens into a
  fixed (E, C, d) buffer (capacity drop, like GShard), so dispatch costs
  gathers/scatters only and the expert matmuls are dense MXU einsums.
* **Group-local routing.** Tokens are routed in groups of ``group_size``
  (default 4096) along the sequence, so capacity buffers stay VMEM/HBM
  friendly at 32k sequence length; for decode (S==1) the batch is one group.
* Router runs in fp32 (standard practice for MoE numerical stability).
* Shared experts (DeepSeek-V2) are a plain dense MLP applied to every token.
* Aux load-balance loss (Switch style) is returned for the training loss.

Sharding: expert weights are laid out (E, in, out) and sharded E→"model"
(expert parallel) with the contraction dim sharded over "data" (FSDP); the
scatter into the (G, E, C, d) buffer is constrained to (data, model, -, -) so
GSPMD lowers the dispatch to an all-to-all over the model axis.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.constraints import constrain
from repro.models.common import dense_init, init_linear, linear, split_keys
from repro.models.mlp import init_mlp, mlp_forward


def init_moe(key, cfg, dtype):
    ks = split_keys(key, 5)
    E, d, ff = cfg.num_experts, cfg.d_model, cfg.expert_ff()
    p = {
        "router": {"w": dense_init(ks[0], d, E, jnp.float32)},  # router in fp32
        "wi": (jax.random.normal(ks[1], (E, d, ff)) / math.sqrt(d)).astype(dtype),
        "wg": (jax.random.normal(ks[2], (E, d, ff)) / math.sqrt(d)).astype(dtype),
        "wo": (jax.random.normal(ks[3], (E, ff, d)) / math.sqrt(ff)).astype(dtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(ks[4], d, ff * cfg.num_shared_experts, dtype, "swiglu")
    return p


def _route_group(tokens, router_logits, k: int, capacity: int, E: int):
    """Route one group of tokens. tokens: (T, d); logits: (T, E) fp32.

    Returns (expert_in (E, C, d), slot (T, k), weights (T, k), aux_loss,
    inv_tok (E*C,), w_slot (E*C,)) — inv_tok/w_slot drive the scatter-add
    combine (which token each slot holds and its combine weight).
    """
    T, d = tokens.shape
    probs = jax.nn.softmax(router_logits, axis=-1)  # (T, E)
    top_w, top_e = jax.lax.top_k(probs, k)  # (T, k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, axis=-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * sum_e f_e * P_e
    assign_frac = jnp.mean(
        (jax.nn.one_hot(top_e, E, dtype=jnp.float32)).sum(axis=1), axis=0
    )  # (E,)
    mean_prob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(assign_frac * mean_prob)

    # flatten assignments and sort by expert id
    flat_e = top_e.reshape(-1)  # (T*k,)
    flat_tok = jnp.repeat(jnp.arange(T), k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    sorted_tok = flat_tok[order]
    sorted_w = top_w.reshape(-1)[order]
    # rank of each assignment within its expert
    start = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")  # (E,)
    rank = jnp.arange(T * k) - start[sorted_e]
    slot_sorted = jnp.where(rank < capacity, sorted_e * capacity + rank, E * capacity)
    # slot-major metadata: which token each slot holds + its combine weight
    # (overflow assignments drop; empty slots point at the zero row T)
    inv_tok = jnp.full((E * capacity,), T, jnp.int32)
    inv_tok = inv_tok.at[slot_sorted].set(sorted_tok.astype(jnp.int32), mode="drop")
    w_slot = jnp.zeros((E * capacity,), jnp.float32)
    w_slot = w_slot.at[slot_sorted].set(sorted_w, mode="drop")
    # dispatch as ONE slot-indexed gather (not gather-then-scatter): the
    # output is expert-parallel-sharded, so each shard gathers only its own
    # slots from the (replicated-over-model) token block — no all-reduce
    # (§Perf pair-3 iteration 4)
    tokens_pad = jnp.concatenate([tokens, jnp.zeros((1, d), tokens.dtype)])
    buf = tokens_pad[inv_tok]  # (E*C, d)
    # map back: slot for (token, j) in original order (kept for tests)
    slot = jnp.full((T * k,), E * capacity, jnp.int32)
    slot = slot.at[order].set(slot_sorted.astype(jnp.int32), mode="drop")
    return buf.reshape(E, capacity, d), slot.reshape(T, k), top_w, aux, inv_tok, w_slot


def moe_forward(
    p,
    cfg,
    x: jnp.ndarray,  # (B, S, d)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output (B,S,d), aux_loss scalar)."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    group_size = cfg.moe_group_size
    capacity_factor = cfg.moe_capacity_factor

    # grouping: sequence chunks for train/prefill, batch for single-token decode
    if S >= group_size:
        g = group_size
        assert S % g == 0, f"seq {S} not divisible by group {g}"
        xg = x.reshape(B * (S // g), g, d)
    else:
        xg = x.reshape(1, B * S, d) if S == 1 else x.reshape(B, S, d)
    G, T, _ = xg.shape
    capacity = max(int(math.ceil(T * k * capacity_factor / E)), 1)

    logits = (xg.astype(jnp.float32) @ p["router"]["w"].astype(jnp.float32))  # (G,T,E)
    expert_in, slot, top_w, aux, inv_tok, w_slot = jax.vmap(
        partial(_route_group, k=k, capacity=capacity, E=E)
    )(xg, logits)
    # expert_in: (G, E, C, d) — constraining E to "model" makes GSPMD lower
    # the dispatch scatter as an all-to-all over the expert-parallel axis
    expert_in = constrain(expert_in, "data", "model", None, None)
    h = jnp.einsum("gecd,edf->gecf", expert_in, p["wi"])
    hg = jnp.einsum("gecd,edf->gecf", expert_in, p["wg"])
    h = jax.nn.silu(h) * hg
    out_e = jnp.einsum("gecf,efd->gecd", h, p["wo"])  # (G, E, C, d)

    # combine: scatter-add each slot's weighted output into its token row.
    # Each expert-parallel shard contributes its local slots and GSPMD
    # combines with ONE psum of (G, T, d) — a take_along_axis gather here
    # would instead all-reduce the k-times-larger (G, T*k, d) tensor
    # (§Perf pair-3 iteration 3).
    out_flat = out_e.reshape(G, E * capacity, d)
    weighted = out_flat * w_slot[..., None].astype(out_flat.dtype)

    def combine_one(flat, inv):
        y = jnp.zeros((T + 1, d), flat.dtype)
        return y.at[inv].add(flat, mode="drop")[:T]

    y = jax.vmap(combine_one)(weighted, inv_tok)
    y = constrain(y, "data", None, None)
    y = y.reshape(B, S, d)

    if "shared" in p:
        y = y + mlp_forward(p["shared"], x)
    return y, jnp.mean(aux)
