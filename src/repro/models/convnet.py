"""The paper's policy/value CNNs.

``arch_nips``  — Mnih et al. 2013 network adapted to actor-critic (paper §5.1):
    conv 16x8x8 s4, conv 32x4x4 s2, dense 256.
``arch_nature`` — Mnih et al. 2015 adaptation:
    conv 32x8x8 s4, conv 64x4x4 s2, conv 64x3x3 s1, dense 512.

Input: (B, 84, 84, 4) stacked grayscale frames in [0, 1] (paper §5.1
pre-processing: action repeat 4, per-pixel max of the two latest frames,
84x84 rescale).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import dtype_of, init_linear, linear, split_keys


def init_cnn(key, cfg):
    dtype = dtype_of(cfg.param_dtype)
    ks = split_keys(key, len(cfg.cnn_spec) + 1)
    p = {"convs": []}
    in_ch = cfg.obs_shape[-1]
    size = cfg.obs_shape[0]
    for i, (feat, kern, stride) in enumerate(cfg.cnn_spec):
        std = 1.0 / math.sqrt(kern * kern * in_ch)
        p["convs"].append(
            {
                "w": (jax.random.normal(ks[i], (kern, kern, in_ch, feat)) * std).astype(dtype),
                "b": jnp.zeros((feat,), dtype),
            }
        )
        in_ch = feat
        size = (size - kern) // stride + 1
    if cfg.cnn_spec:
        flat = size * size * in_ch
    else:  # pure-MLP trunk on flattened observations (vector envs)
        flat = int(math.prod(cfg.obs_shape))
    p["dense"] = init_linear(ks[-1], flat, cfg.cnn_dense, dtype, bias=True)
    return p


def cnn_forward(p, cfg, obs):
    """obs: (B, H, W, C) float -> (B, cnn_dense)."""
    x = obs.astype(dtype_of(cfg.compute_dtype))
    for conv, (feat, kern, stride) in zip(p["convs"], cfg.cnn_spec):
        x = jax.lax.conv_general_dilated(
            x, conv["w"], window_strides=(stride, stride), padding="VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        ) + conv["b"]
        x = jax.nn.relu(x)
    x = x.reshape(x.shape[0], -1)
    return jax.nn.relu(linear(p["dense"], x))
