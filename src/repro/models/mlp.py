"""Feed-forward blocks: SwiGLU (llama-style) and GELU (classic)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import init_linear, linear, split_keys


def init_mlp(key, d_model: int, d_ff: int, dtype, kind: str = "swiglu"):
    ks = split_keys(key, 3)
    if kind == "swiglu":
        return {
            "wi": init_linear(ks[0], d_model, d_ff, dtype),
            "wg": init_linear(ks[1], d_model, d_ff, dtype),
            "wo": init_linear(ks[2], d_ff, d_model, dtype),
        }
    return {
        "wi": init_linear(ks[0], d_model, d_ff, dtype),
        "wo": init_linear(ks[2], d_ff, d_model, dtype),
    }


def mlp_forward(p, x):
    if "wg" in p:
        h = jax.nn.silu(linear(p["wi"], x)) * linear(p["wg"], x)
    else:
        h = jax.nn.gelu(linear(p["wi"], x))
    return linear(p["wo"], h)
