"""Attention variants: GQA (+ sliding window), MLA (DeepSeek-V2/MiniCPM3 style),
cross-attention, and KV-cache decode paths.

Design notes (TPU adaptation):

* The full-sequence path uses **chunked online-softmax attention** — a
  ``lax.scan`` over KV blocks carrying (max, denom, acc). This bounds the
  materialized score tensor to ``(B, S_q, H, block_k)`` instead of
  ``(B, S_q, H, S_k)``, which is what makes the 32k-prefill dry-run fit in
  HBM. The Pallas kernel in ``repro/kernels/flash_attention.py`` is the fused
  single-kernel twin of this algorithm; this XLA version is the reference /
  dry-run path (the container is CPU-only).
* MLA caches the **compressed** latent (c_kv ‖ k_rope) —`kv_lora + rope_dim`
  floats per token regardless of head count. Two decode paths are provided:
  ``naive`` (reconstruct per-head K/V from the latent each step — the
  faithful-to-published-description baseline) and ``absorb`` (fold W_uk into
  the query and W_uv into the output so attention runs in latent space).
  The absorb path is a §Perf hillclimb subject.
* Sliding-window decode uses a ring-buffer cache of ``window`` slots so the
  ``long_500k`` shape has O(window), not O(S), memory.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.constraints import constrain, mesh_axis_size
from repro.models.common import (
    apply_rope,
    dtype_of,
    init_linear,
    init_rmsnorm,
    linear,
    rmsnorm,
    split_keys,
)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Chunked online-softmax attention (full-sequence path)
# ---------------------------------------------------------------------------


def _block_mask(q_pos, k_pos, Sk, causal, window):
    mask = k_pos[None, :] < Sk  # mask padding
    if causal:
        mask = mask & (k_pos[None, :] <= q_pos[:, None])
    if window:
        mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
    return mask


def _flash_fwd(q, k, v, causal, window, q_offset, block_k, scale, Sk):
    """Forward online-softmax block scan. q pre-scaled, K/V pre-padded.

    q: (B, Sq, Hkv, G, D); k/v: (nb, B, block, Hkv, D[v]).
    Returns (out (B,Sq,Hkv,G,Dv) fp32, lse (B,Sq,Hkv,G) fp32).
    """
    B, Sq, Hkv, G, D = q.shape
    n_blocks = k.shape[0]
    q_pos = jnp.arange(Sq) + q_offset

    def body(carry, xs):
        m, l, acc = carry
        blk_idx, k_blk, v_blk = xs
        k_pos = blk_idx * block_k + jnp.arange(block_k)
        s = jnp.einsum("bqhgd,bkhd->bqhgk", q, k_blk,
                       preferred_element_type=jnp.float32)
        s = jnp.where(_block_mask(q_pos, k_pos, Sk, causal, window)
                      [None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        # P in the model dtype for the PV matmul (flash-standard); fp32 row
        # sums keep the softmax normalization exact.
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p.astype(v_blk.dtype), v_blk,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, G), jnp.float32)
    acc0 = jnp.zeros((B, Sq, Hkv, G, v.shape[-1]), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0),
                                  (jnp.arange(n_blocks), k, v))
    l = jnp.maximum(l, 1e-30)
    return acc / l[..., None], m + jnp.log(l)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash_attention_xla(q, k, v, causal, window, q_offset, block_k, scale, Sk):
    out, _ = _flash_fwd(q, k, v, causal, window, q_offset, block_k, scale, Sk)
    return out


def _flash_attention_xla_fwd(q, k, v, causal, window, q_offset, block_k, scale, Sk):
    out, lse = _flash_fwd(q, k, v, causal, window, q_offset, block_k, scale, Sk)
    return out, (q, k, v, out, lse)


def _flash_attention_xla_bwd(causal, window, q_offset, block_k, scale, Sk,
                             res, d_out):
    """Flash-style backward: recompute P per KV block from (q, k, lse) —
    O(block) memory instead of materializing the S² scan residuals that the
    autodiff of the forward scan would store (§Perf pair-3 iteration 1)."""
    q, k, v, out, lse = res
    B, Sq, Hkv, G, D = q.shape
    n_blocks = k.shape[0]
    q_pos = jnp.arange(Sq) + q_offset
    delta = jnp.sum(d_out * out, axis=-1)  # (B,Sq,Hkv,G) fp32
    dtype = q.dtype

    def body(dq_acc, xs):
        blk_idx, k_blk, v_blk = xs
        k_pos = blk_idx * block_k + jnp.arange(block_k)
        s = jnp.einsum("bqhgd,bkhd->bqhgk", q, k_blk,
                       preferred_element_type=jnp.float32)
        mask = _block_mask(q_pos, k_pos, Sk, causal, window)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])  # masked -> exp(-inf) = 0
        p_lo = p.astype(dtype)
        dv_blk = jnp.einsum("bqhgk,bqhgd->bkhd", p_lo, d_out.astype(dtype),
                            preferred_element_type=jnp.float32)
        dp = jnp.einsum("bqhgd,bkhd->bqhgk", d_out.astype(dtype), v_blk,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - delta[..., None])
        ds_lo = ds.astype(dtype)
        dq_acc = dq_acc + jnp.einsum("bqhgk,bkhd->bqhgd", ds_lo, k_blk,
                                     preferred_element_type=jnp.float32)
        dk_blk = jnp.einsum("bqhgk,bqhgd->bkhd", ds_lo, q,
                            preferred_element_type=jnp.float32)
        return dq_acc, (dk_blk.astype(k_blk.dtype), dv_blk.astype(v_blk.dtype))

    dq0 = jnp.zeros((B, Sq, Hkv, G, D), jnp.float32)
    dq, (dk, dv) = jax.lax.scan(body, dq0, (jnp.arange(n_blocks), k, v))
    # dq is w.r.t. the pre-scaled q; the caller's scaling is outside the vjp
    return dq.astype(q.dtype), dk, dv


_flash_attention_xla.defvjp(_flash_attention_xla_fwd, _flash_attention_xla_bwd)


def chunked_attention(
    q: jnp.ndarray,  # (B, Sq, Hq, D)
    k: jnp.ndarray,  # (B, Sk, Hkv, D)
    v: jnp.ndarray,  # (B, Sk, Hkv, Dv)
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    block_k: int = 512,
    scale: Optional[float] = None,
    seq_shard_mode: str = "auto",
) -> jnp.ndarray:
    """Online-softmax attention, scanning KV blocks, with a flash-style
    custom VJP. Returns (B, Sq, Hq, Dv)."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, Dv = v.shape
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)

    # Sharding strategy (§Perf pair-2 iteration 1): when the head count does
    # not divide the model axis (qwen2: 28 heads on 16), GSPMD falls back to
    # sharding the QK contraction dim and all-reduces the full score tensor
    # per KV block (~TBs of wire). Instead we sequence-shard the queries over
    # "model" and replicate K/V — scores stay chip-local.
    msize = mesh_axis_size("model")
    seq_shard = (
        seq_shard_mode == "auto"
        and msize > 1 and Hq % msize != 0 and Sq % msize == 0 and Sq > 1
    )
    if seq_shard:
        q = constrain(q, "data", "model", None, None)
        k = constrain(k, "data", None, None, None)
        v = constrain(v, "data", None, None, None)

    block_k = min(block_k, Sk)
    pad = (-Sk) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_blocks = (Sk + pad) // block_k

    # matmul operands stay in the model dtype (bf16 on the MXU, fp32 in fp32
    # tests); softmax statistics are always fp32.
    qg = (q * scale).reshape(B, Sq, Hkv, G, D)
    kb = k.reshape(B, n_blocks, block_k, Hkv, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, n_blocks, block_k, Hkv, Dv).transpose(1, 0, 2, 3, 4)

    out = _flash_attention_xla(qg, kb, vb, causal, window, q_offset, block_k,
                               scale, Sk)
    return out.reshape(B, Sq, Hq, Dv).astype(q.dtype)


def naive_attention(q, k, v, *, causal=True, window=0, q_offset=0, scale=None):
    """O(S^2)-memory reference attention (tests / tiny models only)."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, Dv = v.shape
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qg = q.reshape(B, Sq, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(Sq) + q_offset
    k_pos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask = mask & (k_pos[None, :] <= q_pos[:, None])
    if window:
        mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA module
# ---------------------------------------------------------------------------


def init_gqa(key, cfg, dtype, *, cross: bool = False):
    """Weights for grouped-query attention (optionally a cross-attn variant)."""
    ks = split_keys(key, 4)
    H, Hkv, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = {
        "wq": init_linear(ks[0], cfg.d_model, H * D, dtype, bias=cfg.qkv_bias),
        "wk": init_linear(ks[1], cfg.d_model, Hkv * D, dtype, bias=cfg.qkv_bias),
        "wv": init_linear(ks[2], cfg.d_model, Hkv * D, dtype, bias=cfg.qkv_bias),
        "wo": init_linear(ks[3], H * D, cfg.d_model, dtype, scale=1.0 / math.sqrt(2 * max(cfg.num_layers, 1))),
    }
    return p


def gqa_forward(
    p,
    cfg,
    x: jnp.ndarray,  # (B, S, d_model)
    *,
    positions: Optional[jnp.ndarray] = None,
    causal: bool = True,
    window: int = 0,
    kv_src: Optional[jnp.ndarray] = None,  # cross-attention source
    use_rope: bool = True,
    block_k: int = 512,
):
    B, S, _ = x.shape
    H, Hkv, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    src = x if kv_src is None else kv_src
    Sk = src.shape[1]
    q = linear(p["wq"], x).reshape(B, S, H, D)
    k = linear(p["wk"], src).reshape(B, Sk, Hkv, D)
    v = linear(p["wv"], src).reshape(B, Sk, Hkv, D)
    if use_rope and kv_src is None:
        pos = positions if positions is not None else jnp.arange(S)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    out = chunked_attention(q, k, v, causal=causal, window=window, block_k=block_k,
                            seq_shard_mode=cfg.attn_seq_shard)
    return linear(p["wo"], out.reshape(B, S, H * D))


def gqa_prefill(p, cfg, x, *, window: int = 0, block_k: int = 512):
    """Forward that also returns the KV cache contents (roped K, V)."""
    B, S, _ = x.shape
    H, Hkv, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = linear(p["wq"], x).reshape(B, S, H, D)
    k = linear(p["wk"], x).reshape(B, S, Hkv, D)
    v = linear(p["wv"], x).reshape(B, S, Hkv, D)
    pos = jnp.arange(S)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    out = chunked_attention(q, k, v, causal=True, window=window, block_k=block_k,
                            seq_shard_mode=cfg.attn_seq_shard)
    return linear(p["wo"], out.reshape(B, S, H * D)), (k, v)


def init_gqa_cache(cfg, batch: int, max_len: int, dtype):
    """Ring buffer when sliding window is active, else full-length cache."""
    slots = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    Hkv, D = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, slots, Hkv, D), dtype),
        "v": jnp.zeros((batch, slots, Hkv, D), dtype),
    }


def gqa_decode(
    p,
    cfg,
    x: jnp.ndarray,  # (B, 1, d_model)
    cache,
    pos,  # scalar int32 (shared position), or (B,) int32 per-row positions
    *,
    window: int = 0,
):
    """Single-token decode against the cache. Returns (out, new_cache).

    ``pos`` may be a scalar (every row at the same position — the lockstep
    launcher) or a ``(B,)`` vector giving each batch row its own position
    (the serving plane's slot-managed decode, where requests join and
    leave mid-flight). Every op is row-independent in both modes: row
    ``b``'s output depends only on row ``b``'s token, position and cache
    row, which is what makes the serving plane's per-request bitwise pin
    possible.
    """
    B = x.shape[0]
    H, Hkv, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    slots = cache["k"].shape[1]
    q = linear(p["wq"], x).reshape(B, 1, H, D)
    k = linear(p["wk"], x).reshape(B, 1, Hkv, D)
    v = linear(p["wv"], x).reshape(B, 1, Hkv, D)
    slot_idx = jnp.arange(slots)
    win = window if window else slots
    if jnp.ndim(pos) == 0:
        pos_arr = jnp.full((1,), pos, jnp.int32)
        q = apply_rope(q, pos_arr, cfg.rope_theta)
        k = apply_rope(k, pos_arr, cfg.rope_theta)

        write = pos % slots  # ring write (== pos when full-length cache)
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, write, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, write, 0, 0))
        if window == 0 and cfg.sliding_window == 0:
            valid = slot_idx <= pos
        else:
            # ring buffer: a slot holds token (pos - ((write - i) % slots));
            # valid iff its age < min(window, pos+1)
            age = (write - slot_idx) % slots
            valid = age < jnp.minimum(win, pos + 1)
        maskb = valid[None, None, None, :]
    else:
        # per-row positions: rope by (B,1) positions, one-hot where-write
        # into each row's own slot, per-row validity mask
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k = apply_rope(k, pos[:, None], cfg.rope_theta)

        write = pos % slots  # (B,)
        hit = slot_idx[None, :] == write[:, None]  # (B, slots)
        ck = jnp.where(hit[:, :, None, None], k.astype(cache["k"].dtype), cache["k"])
        cv = jnp.where(hit[:, :, None, None], v.astype(cache["v"].dtype), cache["v"])
        if window == 0 and cfg.sliding_window == 0:
            valid = slot_idx[None, :] <= pos[:, None]
        else:
            age = (write[:, None] - slot_idx[None, :]) % slots
            valid = age < jnp.minimum(win, pos[:, None] + 1)
        maskb = valid[:, None, None, :]

    G = H // Hkv
    qg = (q * (1.0 / math.sqrt(D))).reshape(B, Hkv, G, D).astype(ck.dtype)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, ck, preferred_element_type=jnp.float32)
    s = jnp.where(maskb, s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", pr.astype(cv.dtype), cv,
                     preferred_element_type=jnp.float32)
    out = out.reshape(B, 1, H * D).astype(x.dtype)
    return linear(p["wo"], out), {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention)
# ---------------------------------------------------------------------------


def init_mla(key, cfg, dtype):
    ks = split_keys(key, 6)
    H = cfg.num_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    p = {}
    if cfg.q_lora_rank:
        p["wdq"] = init_linear(ks[0], cfg.d_model, cfg.q_lora_rank, dtype)
        p["q_norm"] = init_rmsnorm(cfg.q_lora_rank, dtype)
        p["wuq"] = init_linear(ks[1], cfg.q_lora_rank, H * qk, dtype)
    else:
        p["wq"] = init_linear(ks[0], cfg.d_model, H * qk, dtype)
    p["wdkv"] = init_linear(ks[2], cfg.d_model, cfg.kv_lora_rank + cfg.qk_rope_dim, dtype)
    p["kv_norm"] = init_rmsnorm(cfg.kv_lora_rank, dtype)
    # W_ukv maps latent -> per-head (k_nope || v)
    p["wukv"] = init_linear(
        ks[3], cfg.kv_lora_rank, H * (cfg.qk_nope_dim + cfg.v_head_dim), dtype
    )
    p["wo"] = init_linear(
        ks[4], H * cfg.v_head_dim, cfg.d_model, dtype,
        scale=1.0 / math.sqrt(2 * max(cfg.num_layers, 1)),
    )
    return p


def _mla_queries(p, cfg, x):
    B, S, _ = x.shape
    H = cfg.num_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    if cfg.q_lora_rank:
        q = linear(p["wuq"], rmsnorm(p["q_norm"], linear(p["wdq"], x), cfg.norm_eps))
    else:
        q = linear(p["wq"], x)
    q = q.reshape(B, S, H, qk)
    return jnp.split(q, [cfg.qk_nope_dim], axis=-1)  # q_nope, q_rope


def _mla_latent(p, cfg, x):
    """Compressed per-token latent: (c_kv normalized, k_rope un-roped)."""
    ckv = linear(p["wdkv"], x)
    c, k_rope = jnp.split(ckv, [cfg.kv_lora_rank], axis=-1)
    return rmsnorm(p["kv_norm"], c, cfg.norm_eps), k_rope


def mla_forward(p, cfg, x, *, positions=None, window: int = 0, block_k: int = 512,
                return_cache: bool = False):
    """Full-sequence MLA (train / prefill)."""
    B, S, _ = x.shape
    H = cfg.num_heads
    pos = positions if positions is not None else jnp.arange(S)
    q_nope, q_rope = _mla_queries(p, cfg, x)
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
    c, k_rope = _mla_latent(p, cfg, x)
    k_rope = apply_rope(k_rope[:, :, None, :], pos, cfg.rope_theta)  # (B,S,1,rope)
    kv = linear(p["wukv"], c).reshape(B, S, H, cfg.qk_nope_dim + cfg.v_head_dim)
    k_nope, v = jnp.split(kv, [cfg.qk_nope_dim], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, cfg.qk_rope_dim))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    out = chunked_attention(q, k, v, causal=True, window=window, block_k=block_k,
                            scale=scale, seq_shard_mode=cfg.attn_seq_shard)
    y = linear(p["wo"], out.reshape(B, S, H * cfg.v_head_dim))
    if return_cache:
        return y, (c, k_rope[:, :, 0, :])
    return y


def init_mla_cache(cfg, batch: int, max_len: int, dtype):
    slots = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    return {
        "c": jnp.zeros((batch, slots, cfg.kv_lora_rank), dtype),
        "kr": jnp.zeros((batch, slots, cfg.qk_rope_dim), dtype),
    }


def mla_decode(p, cfg, x, cache, pos, *, window: int = 0):
    """Single-token MLA decode.

    cfg.mla_absorb selects the latent-space path (W_uk absorbed into q,
    W_uv into the output) versus the naive path that reconstructs all
    per-head K/V from the latent every step. ``pos`` is a scalar (shared
    position) or a ``(B,)`` vector of per-row positions (serving plane) —
    see ``gqa_decode``; both modes are row-independent.
    """
    B = x.shape[0]
    H = cfg.num_heads
    slots = cache["c"].shape[1]
    slot_idx = jnp.arange(slots)
    win = window if window else slots
    q_nope, q_rope = _mla_queries(p, cfg, x)  # (B,1,H,*)
    c_new, kr_new = _mla_latent(p, cfg, x)  # (B,1,kv_lora), (B,1,rope)

    if jnp.ndim(pos) == 0:
        pos_arr = jnp.full((1,), pos, jnp.int32)
        q_rope = apply_rope(q_rope, pos_arr, cfg.rope_theta)
        kr_new = apply_rope(kr_new[:, :, None, :], pos_arr, cfg.rope_theta)[:, :, 0, :]

        write = pos % slots
        cc = jax.lax.dynamic_update_slice(cache["c"], c_new.astype(cache["c"].dtype), (0, write, 0))
        ckr = jax.lax.dynamic_update_slice(cache["kr"], kr_new.astype(cache["kr"].dtype), (0, write, 0))
        if cfg.sliding_window == 0 and window == 0:
            valid = slot_idx <= pos
        else:
            age = (write - slot_idx) % slots
            valid = age < jnp.minimum(win, pos + 1)
        maskb = valid[None, None, :]
    else:
        q_rope = apply_rope(q_rope, pos[:, None], cfg.rope_theta)
        kr_new = apply_rope(kr_new[:, :, None, :], pos[:, None],
                            cfg.rope_theta)[:, :, 0, :]

        write = pos % slots  # (B,)
        hit = slot_idx[None, :] == write[:, None]  # (B, slots)
        cc = jnp.where(hit[:, :, None], c_new.astype(cache["c"].dtype), cache["c"])
        ckr = jnp.where(hit[:, :, None], kr_new.astype(cache["kr"].dtype), cache["kr"])
        if cfg.sliding_window == 0 and window == 0:
            valid = slot_idx[None, :] <= pos[:, None]
        else:
            age = (write[:, None] - slot_idx[None, :]) % slots
            valid = age < jnp.minimum(win, pos[:, None] + 1)
        maskb = valid[:, None, :]

    scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    nope, vdim, rank = cfg.qk_nope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    wukv = p["wukv"]["w"].reshape(rank, H, nope + vdim)
    w_uk, w_uv = wukv[..., :nope], wukv[..., nope:]  # (rank,H,nope),(rank,H,v)

    # decode math keeps cache-dtype (bf16) matmul operands with fp32
    # accumulation — upcasting the cache would make XLA materialize fp32
    # copies of the whole cache per layer (§Perf pair-1 iteration 2)
    f32 = jnp.float32
    if cfg.mla_absorb:
        # latent-space attention: O(S·rank) per head pair, no K/V expansion
        qn = q_nope[:, 0]  # (B,H,nope)
        q_lat = jnp.einsum("bhn,rhn->bhr", qn, w_uk, preferred_element_type=f32)
        s = jnp.einsum("bhr,bkr->bhk", q_lat.astype(cc.dtype), cc,
                       preferred_element_type=f32)
        s = s + jnp.einsum("bhr,bkr->bhk", q_rope[:, 0].astype(ckr.dtype), ckr,
                           preferred_element_type=f32)
        s = jnp.where(maskb, s * scale, NEG_INF)
        pr = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhk,bkr->bhr", pr.astype(cc.dtype), cc,
                           preferred_element_type=f32)  # (B,H,rank)
        out = jnp.einsum("bhr,rhv->bhv", o_lat.astype(w_uv.dtype), w_uv,
                         preferred_element_type=f32)
    else:
        # naive: expand the whole cache to per-head K/V every step
        kv = jnp.einsum("bkr,rhe->bkhe", cc, wukv, preferred_element_type=cc.dtype)
        k_nope, v = kv[..., :nope], kv[..., nope:]
        qn = q_nope[:, 0].astype(kv.dtype)
        s = jnp.einsum("bhn,bkhn->bhk", qn, k_nope, preferred_element_type=f32)
        s = s + jnp.einsum("bhr,bkr->bhk", q_rope[:, 0].astype(ckr.dtype), ckr,
                           preferred_element_type=f32)
        s = jnp.where(maskb, s * scale, NEG_INF)
        pr = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhk,bkhv->bhv", pr.astype(v.dtype), v,
                         preferred_element_type=f32)

    out = out.reshape(B, 1, H * vdim).astype(x.dtype)
    return linear(p["wo"], out), {"c": cc, "kr": ckr}
