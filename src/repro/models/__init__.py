"""Unified policy/value model API (paper §3: the framework is model-agnostic).

Every backbone — the paper's CNNs and all ten assigned architectures —
exposes the same functional surface consumed by the PAAC core and launchers:

* ``init_policy(key, cfg)``                    -> params
* ``policy_apply(params, cfg, obs/tokens, …)`` -> (logits, values) full pass
* ``init_policy_cache(cfg, batch, max_len)``   -> decode cache (token models)
* ``policy_decode(params, cfg, cache, tok, pos)`` -> (logits, value, cache)
* ``policy_prefill(params, cfg, tokens, …)``   -> (logits, value, cache)
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.models.convnet import cnn_forward, init_cnn
from repro.models.heads import apply_heads, init_heads
from repro.models.common import split_keys


def init_policy(key, cfg):
    ks = split_keys(key, 2)
    if cfg.family == "cnn":
        trunk = init_cnn(ks[0], cfg)
    else:
        trunk = tfm.init_model(ks[0], cfg)
    return {"trunk": trunk, "heads": init_heads(ks[1], cfg)}


def policy_apply(params, cfg, obs, prefix_embeds=None, *, train: bool = False,
                 window: Optional[int] = None):
    """Full batched evaluation.

    CNN family: obs (B, ...) -> (logits (B,A), values (B,)).
    Token families: obs = tokens (B,S) -> per-position (logits (B,S,A),
    values (B,S)) plus aux dict.
    """
    if cfg.family == "cnn":
        h = cnn_forward(params["trunk"], cfg, obs)
        logits, value = apply_heads(params["heads"], cfg, h)
        return logits, value, {}
    hidden, aux = tfm.forward(params["trunk"], cfg, obs, prefix_embeds,
                              train=train, window=window)
    embed = params["trunk"]["embed"] if cfg.tie_policy_head else None
    logits, values = apply_heads(params["heads"], cfg, hidden, embed)
    return logits, values, aux


def init_policy_cache(cfg, batch: int, max_len: int, dtype=None):
    return tfm.init_cache(cfg, batch, max_len, dtype)


def policy_decode(params, cfg, cache, token, pos, *, window: Optional[int] = None):
    """One decode step: token (B,1) -> (logits (B,A), value (B,), cache)."""
    hidden, cache = tfm.decode_step(params["trunk"], cfg, cache, token, pos, window=window)
    embed = params["trunk"]["embed"] if cfg.tie_policy_head else None
    logits, value = apply_heads(params["heads"], cfg, hidden, embed)
    return logits[:, 0], value[:, 0], cache


def policy_prefill(params, cfg, tokens, prefix_embeds=None, *,
                   window: Optional[int] = None, max_len: Optional[int] = None):
    hidden, cache = tfm.prefill(params["trunk"], cfg, tokens, prefix_embeds,
                                window=window, max_len=max_len)
    embed = params["trunk"]["embed"] if cfg.tie_policy_head else None
    logits, values = apply_heads(params["heads"], cfg, hidden, embed)
    return logits, values, cache
