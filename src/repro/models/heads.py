"""PAAC two-headed output (paper §4).

A single trunk feeds two output layers: a softmax policy head (one logit per
action — for token-manipulation environments the action space is the
vocabulary) and a single linear value node.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.distributed.constraints import constrain
from repro.models.common import dtype_of, init_linear, linear, split_keys


def init_heads(key, cfg):
    dtype = dtype_of(cfg.param_dtype)
    ks = split_keys(key, 2)
    p = {"value": init_linear(ks[1], cfg.d_model, 1, dtype, bias=True)}
    if not cfg.tie_policy_head:
        p["policy"] = init_linear(ks[0], cfg.d_model, cfg.actions(), dtype)
    return p


def apply_heads(p, cfg, hidden, embed=None):
    """hidden: (..., d_model) -> (logits (..., A) fp32, value (...,) fp32)."""
    if cfg.tie_policy_head:
        logits = hidden @ embed.T
    else:
        logits = linear(p["policy"], hidden)
    axes = ("data",) + (None,) * (logits.ndim - 2) + ("model",)
    logits = constrain(logits.astype(jnp.float32), *axes)
    value = linear(p["value"], hidden).astype(jnp.float32)[..., 0]
    return logits, value
