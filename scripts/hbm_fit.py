"""Per-chip HBM fit table: params + optimizer state bytes under the
production sharding, per architecture (train_4k configuration).

    PYTHONPATH=src python scripts/hbm_fit.py
"""
import jax
import jax.numpy as jnp
from jax.sharding import AbstractMesh

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.distributed.sharding import param_specs
from repro.models import init_policy

MESH = AbstractMesh((16, 16), ("data", "model"))
HBM = 16e9  # TPU v5e


def shard_bytes(sds, specs):
    sizes = dict(MESH.shape)

    def axis_size(a):
        if a is None:
            return 1
        if isinstance(a, tuple):
            n = 1
            for x in a:
                n *= sizes[x]
            return n
        return sizes[a]

    total = 0
    for (path, spec), (_, leaf) in zip(
        jax.tree_util.tree_flatten_with_path(specs)[0],
        jax.tree_util.tree_flatten_with_path(sds)[0],
    ):
        elems = leaf.size
        for dim, a in zip(leaf.shape, tuple(spec) + (None,) * (leaf.ndim - len(spec))):
            s = axis_size(a)
            if s > 1 and dim % s == 0:
                elems //= s
        total += elems * leaf.dtype.itemsize
    return total


def main():
    print("| arch | mode | params GB/chip | RMSProp fp32 GB/chip | total GB/chip | fits 16GB (w/ activations headroom) |")
    print("|---|---|---|---|---|---|")
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        sds = jax.eval_shape(lambda c=cfg: init_policy(jax.random.PRNGKey(0), c))
        mode = "fsdp_tp"
        p_specs = param_specs(sds, MESH, mode)
        pb = shard_bytes(sds, p_specs)
        # RMSProp "sq" state mirrors params in fp32, same sharding
        sq = jax.tree_util.tree_map(
            lambda l: jax.ShapeDtypeStruct(l.shape, jnp.float32), sds
        )
        sb = shard_bytes(sq, p_specs)
        tot = pb + sb
        print(
            f"| {arch} | {mode} | {pb/1e9:.2f} | {sb/1e9:.2f} | {tot/1e9:.2f} | "
            f"{'yes' if tot < 10e9 else 'NO'} |"
        )


if __name__ == "__main__":
    main()
