#!/usr/bin/env python
"""Run repro-lint from the repo root without PYTHONPATH plumbing.

``python scripts/lint.py``            lints ``src/`` (the CI gate).
``python scripts/lint.py --diff``     lints only ``.py`` files changed
                                      vs ``main`` (plus untracked ones),
                                      for a fast pre-push check.
``python scripts/lint.py PATH ...``   lints explicit paths.

Exit codes mirror ``python -m repro.analysis.lint``: 0 clean, 1
findings, 2 usage error. ``--diff`` with no changed files is clean.
"""
import argparse
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def changed_py_files(base: str) -> list:
    out = subprocess.run(
        ["git", "diff", "--name-only", "--diff-filter=d", base, "--"],
        cwd=REPO, capture_output=True, text=True, check=True,
    ).stdout
    untracked = subprocess.run(
        ["git", "ls-files", "--others", "--exclude-standard"],
        cwd=REPO, capture_output=True, text=True, check=True,
    ).stdout
    files = []
    for line in (out + untracked).splitlines():
        rel = line.strip()
        p = REPO / rel
        # fixtures are deliberately broken — they are the linter's tests
        if rel.startswith("tests/fixtures/"):
            continue
        if rel.endswith(".py") and p.exists():
            files.append(str(p))
    return sorted(set(files))


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("paths", nargs="*", default=None,
                        help="files/dirs to lint (default: src)")
    parser.add_argument("--diff", action="store_true",
                        help="lint only .py files changed vs --base")
    parser.add_argument("--base", default="main",
                        help="diff base ref for --diff (default: main)")
    args = parser.parse_args(argv)
    if args.diff and args.paths:
        parser.error("--diff and explicit paths are mutually exclusive")

    sys.path.insert(0, str(REPO / "src"))
    from repro.analysis import lint as rlint

    if args.diff:
        targets = changed_py_files(args.base)
        if not targets:
            print("repro-lint: no .py files changed vs %s" % args.base,
                  file=sys.stderr)
            return 0
    else:
        targets = args.paths or [str(REPO / "src")]
    return rlint.main(targets)


if __name__ == "__main__":
    sys.exit(main())
