"""Diagnosis tool for hillclimbing: lower one pair, rank the top byte and
collective contributors in the optimized HLO (with loop multipliers).

    PYTHONPATH=src python scripts/diag_pair.py qwen2-7b prefill_32k
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import re  # noqa: E402
import sys  # noqa: E402
from collections import defaultdict  # noqa: E402

from repro.launch.dryrun import lower_pair  # noqa: E402
from repro.launch.hlo_analysis import (  # noqa: E402
    HloModule,
    _BYTE_OPS,
    _COLLECTIVES,
    _group_size,
    _shape_elems_bytes,
    _wire_factor,
)


def diagnose(hlo_path: str, top: int = 20):
    m = HloModule(open(hlo_path).read())
    byte_items = defaultdict(float)
    wire_items = defaultdict(float)

    def called(instr):
        out = []
        mm = re.search(r"calls=%?([\w.\-]+)", instr.attrs)
        if mm:
            out.append((mm.group(1), 1.0))
        mm = re.search(r"body=%?([\w.\-]+)", instr.attrs)
        if mm:
            mc = re.search(r"condition=%?([\w.\-]+)", instr.attrs)
            out.append((mm.group(1), float(m.trip_count(mc.group(1))) if mc else 1.0))
        return out

    def walk(comp, mult, cb):
        for instr in m.computations.get(comp, []):
            op = instr.op
            meta = re.search(r'op_name="([^"]+)"', instr.line)
            tag = (meta.group(1)[-90:] if meta else instr.name)[-90:]
            for c in _COLLECTIVES:
                if op == c or op.startswith(c + "-start"):
                    _, nb = _shape_elems_bytes(instr.type_str)
                    n = _group_size(instr.line)
                    wire_items[(c, instr.type_str[:60], tag)] += (
                        mult * nb * _wire_factor(c, n)
                    )
            if cb and op in _BYTE_OPS:
                _, rb = _shape_elems_bytes(instr.type_str)
                if op in ("dynamic-slice", "slice", "gather"):
                    b = 2 * rb
                elif op == "dynamic-update-slice" and len(instr.operands) >= 2:
                    _, ub = _shape_elems_bytes(m.shape_of.get(instr.operands[1], ""))
                    b = 2 * ub
                else:
                    ob = sum(
                        _shape_elems_bytes(m.shape_of.get(o, ""))[1]
                        for o in set(instr.operands)
                    )
                    b = rb + ob
                byte_items[(op, instr.type_str[:60], tag)] += mult * b
            for sub, mm2 in called(instr):
                walk(sub, mult * mm2, cb and op in ("while", "conditional", "call"))

    walk(m.entry, 1.0, True)
    print("==== top HBM-byte contributors ====")
    for (op, ty, tag), v in sorted(byte_items.items(), key=lambda kv: -kv[1])[:top]:
        print(f"{v:12.3e}  {op:22s} {ty:60s} {tag}")
    print("==== top collective wire-byte contributors ====")
    for (op, ty, tag), v in sorted(wire_items.items(), key=lambda kv: -kv[1])[:top]:
        print(f"{v:12.3e}  {op:22s} {ty:60s} {tag}")


if __name__ == "__main__":
    arch, shape = sys.argv[1], sys.argv[2]
    kw = {}
    for a in sys.argv[3:]:
        if a == "--absorb":
            kw["mla_absorb"] = True
        if a == "--tp":
            kw["sharding_mode"] = "tp"
    hlo = f"/tmp/{arch}_{shape}.hlo"
    rep = lower_pair(arch, shape, save_hlo=hlo, **kw)
    t = rep["roofline"]
    print(
        f"terms: compute={t['compute_s']:.3f}s memory={t['memory_s']:.3f}s "
        f"collective={t['collective_s']:.3f}s bottleneck={t['bottleneck']}"
    )
    diagnose(hlo)
